"""Distributed DAWN under shard_map — the multi-pod execution path.

Layout (DESIGN.md §6):
  * sources sharded over the data-parallel axes (``pod`` × ``data``) —
    APSP source blocks are embarrassingly parallel;
  * adjacency sharded over ``model``;
  * per-sweep collective stitches the frontier back together.

Two collective schedules are provided (compared in EXPERIMENTS.md §Perf):

  ``schedule="psum"``        adjacency row-sharded; every sweep psums f32
                             partial counts of shape (S_local, n) — the
                             naive SUMMA-style schedule, 4·S_l·n bytes/sweep.
  ``schedule="allgather"``   adjacency column-sharded; every sweep
                             all-gathers the *boolean* local hit block
                             (S_l · n/C bytes), optionally bit-packed
                             (``bitpack=True`` → S_l · n/(8C) bytes) —
                             32·C× fewer collective bytes than psum.

Both wrap the shared sweep layer: the collective matmul is just another
sweep *form* handed to :func:`repro.core.sweep.sweep_loop`, with Fact-1
convergence overridden by a psum so every shard agrees on termination —
this module carries no loop of its own.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .. import compat
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import sweep as S
from .frontier import UNREACHED, one_hot_frontier, pack_bits, unpack_bits


class ShardedDawnResult(NamedTuple):
    dist: jax.Array      # (S, n) int32
    sweeps: jax.Array    # scalar int32


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def make_sharded_msbfs(mesh: Mesh, *, schedule: str = "allgather",
                       bitpack: bool = True, max_steps: int = 0):
    """Build a jitted multi-source DAWN for ``mesh``.

    Returns fn(adj (n, n) int8, sources (S,) int32) -> ShardedDawnResult.
    ``n`` must divide by mesh model-axis size × 32 (bitpack) and ``S`` by
    the data-parallel extent.
    """
    dp = _dp_axes(mesh)
    model_ax = "model"

    adj_spec = P(model_ax, None) if schedule == "psum" else P(None, model_ax)
    f_spec = P(dp, None)

    def run_local(adj_l, f0_l, dist0_l, steps):
        n = f0_l.shape[1]

        def sweep_fn(f, dist, parent, step):
            if schedule == "psum":
                # adj_l: (n/C, n); f slice for my rows
                row0 = jax.lax.axis_index(model_ax) * adj_l.shape[0]
                f_rows = jax.lax.dynamic_slice_in_dim(f, row0,
                                                      adj_l.shape[0], 1)
                part = jax.lax.dot_general(
                    f_rows.astype(jnp.float32), adj_l.astype(jnp.float32),
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                counts = jax.lax.psum(part, model_ax)        # (S_l, n) f32
                hits = counts > 0
            else:
                # adj_l: (n, n/C) — local columns
                counts = jax.lax.dot_general(
                    f.astype(jnp.float32), adj_l.astype(jnp.float32),
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                hits_l = counts > 0                          # (S_l, n/C)
                if bitpack:
                    packed = pack_bits(hits_l)               # (S_l, n/(32C))
                    gathered = jax.lax.all_gather(
                        packed, model_ax, axis=1, tiled=True)
                    hits = unpack_bits(gathered, n)
                else:
                    hits = jax.lax.all_gather(
                        hits_l, model_ax, axis=1, tiled=True)
            new = hits & (dist == UNREACHED)
            return new, jnp.where(new, step, dist), parent

        def converged(new):
            # Fact 1 must fire on every shard at once: reduce over the
            # whole mesh so the while_loop predicates agree
            return jax.lax.psum(jnp.any(new).astype(jnp.int32),
                                dp + (model_ax,)) == 0

        st = S.sweep_loop((sweep_fn,),
                          S.make_state(f0_l, dist0_l, n_forms=1),
                          max_steps=steps, converged=converged)
        return st.dist, st.step

    sharded = compat.shard_map(
        run_local, mesh=mesh,
        in_specs=(adj_spec, f_spec, f_spec, P()),
        out_specs=(f_spec, P()),
        check_vma=False)

    @jax.jit
    def msbfs(adj: jax.Array, sources: jax.Array) -> ShardedDawnResult:
        n = adj.shape[0]
        steps = jnp.int32(max_steps if max_steps else n)
        f0 = one_hot_frontier(sources, n)
        dist0 = jnp.where(f0, 0, jnp.full(f0.shape, UNREACHED))
        dist, sweeps = sharded(adj, f0, dist0, steps)
        return ShardedDawnResult(dist, sweeps)

    return msbfs


def shard_inputs(mesh: Mesh, adj: jax.Array, sources: jax.Array,
                 schedule: str = "allgather"):
    """Device-put inputs with the layout make_sharded_msbfs expects."""
    adj_spec = P("model", None) if schedule == "psum" else P(None, "model")
    adj = jax.device_put(adj, NamedSharding(mesh, adj_spec))
    sources = jax.device_put(sources, NamedSharding(mesh, P(_dp_axes(mesh))))
    return adj, sources
