"""qwen2-72b — dense LM, GQA kv=8, QKV bias.
[arXiv:2407.10671; hf]  80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064."""
from ..models.transformer import LMConfig

CONFIG = LMConfig(
    name="qwen2-72b", n_layers=80, d_model=8192, n_heads=64, n_kv=8,
    d_head=128, d_ff=29568, vocab=152064, act="swiglu", qkv_bias=True,
    rope_theta=1e6)
