"""Edge-chunked eqv2 layer == unchunked (exactness infrastructure test)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro._attic.models import gnn as G


def test_chunked_equals_unchunked():
    rng = np.random.default_rng(0)
    n, e = 40, 120
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    dst = np.where(dst == src, (dst + 1) % n, dst)
    batch = {"species": jnp.asarray(rng.integers(0, 10, n)),
             "pos": jnp.asarray(rng.normal(size=(n, 3)), jnp.float32),
             "src": jnp.asarray(src), "dst": jnp.asarray(dst),
             "node_mask": jnp.ones((n,), bool),
             "graph_id": jnp.zeros((n,), jnp.int32),
             "energy": jnp.zeros((1,), jnp.float32)}
    cfg0 = G.EqV2Config(n_layers=2, d_hidden=16, l_max=2, n_heads=4,
                        n_rbf=8)
    cfg1 = dataclasses.replace(cfg0, edge_chunk=30)
    p = G.eqv2_init(jax.random.PRNGKey(0), cfg0)
    e0 = G.eqv2_forward(p, batch, cfg0, 1)
    e1 = G.eqv2_forward(p, batch, cfg1, 1)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1),
                               rtol=1e-4, atol=1e-5)
