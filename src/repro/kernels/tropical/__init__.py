from .kernel import (fused_minplus_sweep, fused_minplus_multisweep,
                     sparse_relax_sweep)
from .ref import minplus_sweep_ref, sparse_relax_ref

from .. import common, registry


def vmem_bytes(*, form: str = "dense", bs: int = 128, bn: int = 128,
               bk: int = 128, s: int = 64, n_pad: int = 1152,
               eb: int = 128, n: int = 1152, **_) -> int:
    """Resident VMEM of one grid step (docs/ARCHITECTURE.md table).
    Extra keywords are ignored (uniform autotuner call)."""
    if form == "dense":  # f32 fdist + f32 W + f32 dist/acc, i8+f32 out
        return common.push_vmem_bytes(bs, bn, bk, f_itemsize=4, a_itemsize=4,
                                      d_itemsize=4, acc_itemsize=4,
                                      out_itemsizes=(1, 4))
    if form == "fused":  # whole (n, n) f32 weight matrix + resident state
        return common.fused_vmem_bytes(
            bs=bs, n=n, operand_bytes=n * n * 4,
            frontier_bytes=bs * n * 1,
            state_itemsizes=(4,),          # dist f32
            out_itemsizes=(1, 4))          # new i8 + dist f32 out
    assert form == "sparse", form
    # i8 frontier + f32 dist/acc/out + i8 out, whole (S, n_pad) state,
    # plus 3 (1, eb) edge-lane blocks (src/dst int32, w f32)
    return s * n_pad * (1 + 4 + 4 + 4 + 1) + 3 * eb * 4


registry.register(registry.KernelSet(
    semiring="tropical",
    forms={"dense": fused_minplus_sweep, "sparse": sparse_relax_sweep},
    vmem_bytes=vmem_bytes,
    notes="fused min-plus push sweep (settled-bound tile skip) + "
          "edge-parallel sparse relax (interpret-validated; prefer the "
          "dense kernel or the XLA sparse form on real TPUs) + the fused "
          "multi-sweep persistent min-plus kernel (whole weight matrix "
          "resident — the VMEM gate in resolve_fused_steps bounds n)",
    # sparse only: data-dependent gathers/scatters by edge index are not
    # validated under Mosaic compilation and the whole-(S, n_pad) state is
    # VMEM-unbounded in n_pad.  The dense form stays compiled-dispatchable:
    # its per-lane fori_loop/dynamic-slice schedule is the one the boolean
    # pull kernel has always shipped compiled with.
    interpret_only=frozenset({"sparse"}),
    fused_forms={"dense": fused_minplus_multisweep},
))
