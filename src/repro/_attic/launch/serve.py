"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Runs the continuous-batching engine over a reduced config of the selected
LM (or the DIEN scorer for recsys) and reports latency percentiles +
throughput — the local, runnable face of the decode/prefill paths the
dry-run lowers at production scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_arch
from ..models import recsys as R
from ..models import transformer as T
from ..lm_serving import Request, ServingEngine
from .train import reduced_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    family, cfg = get_arch(args.arch)
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)

    if family == "recsys":
        import dataclasses
        cfg = dataclasses.replace(cfg, n_items=5000, n_cats=100,
                                  n_profile=1000, seq_len=20)
        params = R.dien_init(key, cfg)
        from ..data.recsys import click_batch
        fwd = jax.jit(lambda p, b: R.dien_forward(p, b, cfg)[0])
        lat = []
        for i in range(args.requests):
            b = {k: np.asarray(v) for k, v in
                 click_batch(i, cfg, batch=args.slots).items()}
            t0 = time.perf_counter()
            fwd(params, b)[0].block_until_ready()
            lat.append(time.perf_counter() - t0)
        lat_ms = np.array(lat) * 1e3
        print(f"[dien] {args.requests} batches of {args.slots}: "
              f"p50 {np.percentile(lat_ms, 50):.1f}ms "
              f"p99 {np.percentile(lat_ms, 99):.1f}ms")
        return

    cfg = reduced_lm(cfg)
    params = T.init_params(key, cfg)
    eng = ServingEngine(params, cfg, slots=args.slots, max_len=256)
    t0 = time.monotonic()
    for r in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(4, 24))
        eng.submit(Request(rid=r, prompt=prompt.astype(np.int32),
                           max_new=args.max_new))
    done = eng.run_to_completion()
    wall = time.monotonic() - t0
    ttft = [d.t_first - d.t_submit for d in done]
    total_toks = sum(len(d.out) for d in done)
    print(f"[{args.arch} reduced] {len(done)} requests, "
          f"{total_toks} tokens in {wall:.1f}s "
          f"({total_toks / wall:.1f} tok/s); "
          f"TTFT p50 {np.percentile(ttft, 50)*1e3:.0f}ms "
          f"p99 {np.percentile(ttft, 99)*1e3:.0f}ms")


if __name__ == "__main__":
    main()
