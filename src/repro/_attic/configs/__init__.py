from .registry import all_cells, get_arch, list_archs, shapes_for
from . import shapes
