"""Optimizer / checkpoint / compression / fault-tolerance substrate."""
import os
import tempfile

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train import checkpoint as C
from repro.train import compression as CP
from repro.train import fault_tolerance as FT
from repro.train import optimizer as O
from repro.train.train_loop import make_train_step


def _quad_loss(p, batch):
    return jnp.sum((p["w"] - batch["target"]) ** 2)


def test_adamw_converges_quadratic():
    opt = O.adamw(peak_lr=0.1, weight_decay=0.0,
                  schedule=lambda s: jnp.float32(0.1))
    p = {"w": jnp.ones((4,)) * 5}
    state = opt.init(p)
    batch = {"target": jnp.zeros((4,))}
    step = jax.jit(make_train_step(_quad_loss, opt))
    for _ in range(200):
        p, state, m = step(p, state, batch)
    assert float(m["loss"]) < 1e-2


def test_adafactor_converges_quadratic():
    opt = O.adafactor(peak_lr=0.1, schedule=lambda s: jnp.float32(0.1))
    p = {"w": jnp.ones((4, 3)) * 5}
    state = opt.init(p)
    step = jax.jit(make_train_step(
        lambda p, b: jnp.sum((p["w"] - b["target"]) ** 2), opt))
    batch = {"target": jnp.zeros((4, 3))}
    for _ in range(300):
        p, state, m = step(p, state, batch)
    assert float(m["loss"]) < 0.1


def test_adafactor_stacked_leaf_chunked_update_matches_flat():
    """lax.map-chunked update of (L, a, b) leaves == updating each layer."""
    opt = O.adafactor(peak_lr=0.05, schedule=lambda s: jnp.float32(0.05))
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (3, 8, 5))
    g = jax.random.normal(jax.random.fold_in(key, 1), (3, 8, 5))
    st = opt.init({"w": w})
    new_stacked, _, _ = opt.update({"w": w}, {"w": g}, st)
    for l in range(3):
        st_l = opt.init({"w": w[l]})
        new_l, _, _ = opt.update({"w": w[l]}, {"w": g[l]}, st_l)
        np.testing.assert_allclose(np.asarray(new_stacked["w"][l]),
                                   np.asarray(new_l["w"]), rtol=1e-5,
                                   atol=1e-6)


def test_grad_accum_matches_full_batch():
    opt = O.sgd(lr=0.1)
    key = jax.random.PRNGKey(0)
    p = {"w": jax.random.normal(key, (6, 4))}

    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

    batch = {"x": jax.random.normal(jax.random.fold_in(key, 1), (8, 6)),
             "y": jax.random.normal(jax.random.fold_in(key, 2), (8, 4))}
    p1, _, m1 = make_train_step(loss, opt, accum=1)(p, opt.init(p), batch)
    p4, _, m4 = make_train_step(loss, opt, accum=4)(p, opt.init(p), batch)
    # mean-of-microbatch-means == full-batch mean for equal microbatches
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                               rtol=1e-5, atol=1e-6)


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
                "b": {"c": jnp.float32(3.5)}}
        for s in (1, 2, 3, 4):
            C.save(d, s, tree, keep=2)
        assert C.all_steps(d) == [3, 4]
        restored, step = C.restore(d, 4, tree)
        assert step == 4
        for x, y in zip(jax.tree_util.tree_leaves(restored),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))


def test_checkpoint_detects_corruption():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.ones((4,))}
        C.save(d, 1, tree)
        target = os.path.join(d, "step_000000001", "0000.bin")
        with open(target, "r+b") as f:
            f.write(b"\xde\xad")
        with pytest.raises(IOError):
            C.restore(d, 1, tree)


def test_async_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.ones((128, 128))}
        t = C.save(d, 7, tree, blocking=False)
        t.join()
        assert C.latest_step(d) == 7


def test_stale_tmp_dir_is_purged_not_merged():
    """A .tmp left by a crashed earlier write must not leak leftover
    leaf files into the next checkpoint at the same step."""
    with tempfile.TemporaryDirectory() as d:
        stale = os.path.join(d, "step_000000005.tmp")
        os.makedirs(stale)
        with open(os.path.join(stale, "9999.bin"), "wb") as f:
            f.write(b"leftover from a crashed writer")
        C.save(d, 5, {"a": jnp.arange(4)})
        final = os.path.join(d, "step_000000005")
        assert sorted(os.listdir(final)) == ["0000.bin", "MANIFEST.json"]
        restored, _ = C.restore(d, 5, {"a": jnp.arange(4)})
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(4))


def test_step_scan_ignores_stale_tmp_dirs():
    """all_steps/latest_step never surface an in-flight or crashed .tmp,
    even one that already contains a MANIFEST.json."""
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 3, {"a": jnp.ones(2)})
        tmp = os.path.join(d, "step_000000009.tmp")
        os.makedirs(tmp)
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            f.write("{}")
        assert C.all_steps(d) == [3]
        assert C.latest_step(d) == 3


def test_bf16_leaves_survive_raw_bytes_roundtrip():
    tree = {"w": jnp.arange(8, dtype=jnp.bfloat16) / 4,
            "b": jnp.float32(1.5)}
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 1, tree)
        restored, _ = C.restore(d, 1, tree)
        assert restored["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(restored["w"], np.float32),
            np.asarray(tree["w"], np.float32))


def test_gc_retains_newest_n():
    with tempfile.TemporaryDirectory() as d:
        for s in range(1, 6):
            C.save(d, s, {"a": jnp.int32(s)}, keep=3)
        assert C.all_steps(d) == [3, 4, 5]


def test_manifest_meta_roundtrip():
    meta = {"workload": "boolean", "edges_sha": "abc123", "chunks": 7}
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 2, {"a": jnp.ones(3)}, meta=meta)
        assert C.read_manifest(d, 2)["meta"] == meta
        C.save(d, 4, {"a": jnp.ones(3)})
        assert "meta" not in C.read_manifest(d, 4)


def test_async_save_snapshots_buffers_before_returning():
    """save(blocking=False) must deep-copy host buffers before the
    writer thread starts: mutating the array right after submit may not
    tear the checkpoint (np.asarray on a host ndarray is a view)."""
    arr = np.arange(4096, dtype=np.int32)
    with tempfile.TemporaryDirectory() as d:
        t = C.save(d, 1, {"a": arr}, blocking=False)
        arr[:] = -1                      # caller reuses its buffer
        t.join()
        restored, _ = C.restore(d, 1, {"a": arr})
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(4096, dtype=np.int32))


def test_checkpoint_hook_join_and_skip_policies(monkeypatch):
    import threading

    release = threading.Event()
    joined = []

    def fake_save(ckpt_dir, step, tree, *, blocking=True, keep=3,
                  meta=None):
        t = threading.Thread(target=release.wait, daemon=True)
        orig_join = t.join

        def join(*a):
            joined.append(step)
            release.set()
            orig_join(*a)
        t.join = join
        t.start()
        return t

    monkeypatch.setattr(C, "save", fake_save)
    hook = C.CheckpointHook("/nonexistent", keep=2, policy="skip")
    assert hook.submit(1, {}) is True
    assert hook.submit(2, {}) is False       # first write still in flight
    assert hook.skipped == 1 and hook.written == 1
    assert hook.pending is not None and hook.pending.is_alive()
    hook.flush()
    assert hook.pending is None

    release.clear()
    joined.clear()
    hook = C.CheckpointHook("/nonexistent", keep=2)   # policy="join"
    hook.submit(1, {})
    hook.submit(2, {})                       # must join write 1 first
    assert joined == [1]
    assert hook.written == 2 and hook.skipped == 0
    hook.flush()
    with pytest.raises(ValueError):
        C.CheckpointHook("/x", policy="overlap")


def test_int8_compression_error_feedback():
    """With error feedback, compressed-grad SGD still converges."""
    p = {"w": jnp.ones((8,)) * 4}
    ef = CP.init_error_feedback(p)
    lr = 0.05
    for _ in range(300):
        g = {"w": 2 * p["w"]}
        cg, ef = CP.compress_int8(g, ef)
        p = jax.tree.map(lambda a, b: a - lr * b, p, cg)
    assert float(jnp.abs(p["w"]).max()) < 1e-2


def test_topk_compression_shapes_and_bytes():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)),
                          jnp.float32)}
    ef = CP.init_error_feedback(g)
    cg, ef2 = CP.compress_topk(g, ef, frac=0.05)
    nz = int((np.asarray(cg["w"]) != 0).sum())
    assert nz <= int(64 * 64 * 0.05) + 1
    raw, wire = CP.compressed_bytes(g, "topk", 0.05)
    assert wire < raw / 10


def test_first_sweep_does_not_declare_hosts_dead():
    """Regression: last_beat used to initialize to 0.0 while sweep()
    defaulted to time.monotonic(), so a fresh monitor declared every
    host dead before any beat could arrive."""
    mon = FT.HeartbeatMonitor(4, interval_s=10.0, dead_after=3)
    assert mon.sweep() == []
    assert mon.alive_hosts == [0, 1, 2, 3]


def test_heartbeat_injected_clock_never_mixes_time_scales():
    """With clock=, construction / beat / sweep all read the same
    virtual time: hosts die exactly when the virtual clock says so."""
    t = [1000.0]
    mon = FT.HeartbeatMonitor(3, interval_s=10.0, dead_after=3,
                              clock=lambda: t[0])
    assert mon.sweep() == []
    for step in range(1, 8):
        t[0] = 1000.0 + 10.0 * step
        mon.beat(0)
        mon.beat(1)
    assert mon.sweep() == [2]          # never beat since construction
    assert mon.alive_hosts == [0, 1]


def test_straggler_stale_hosts_drop_out_of_the_window():
    """A dead host's final step time must not pollute the median
    forever: with stale_after=, classify() only considers hosts whose
    last sample is recent on the injected clock."""
    t = [0.0]
    det = FT.StragglerDetector(window=8, threshold=3.0, evict_after=2,
                               clock=lambda: t[0], stale_after=5.0)
    for step in range(4):
        t[0] = float(step)
        for h in range(4):
            det.record(h, 10.0 if h == 3 else 1.0)
    strag, _ = det.classify()
    assert strag == [3]
    # host 3 dies; the others keep stepping past the staleness horizon
    for step in range(4, 12):
        t[0] = float(step)
        for h in range(3):
            det.record(h, 1.0)
    strag, _ = det.classify()
    assert 3 not in strag
    assert det.classify(now=t[0]) == det.classify()


def test_heartbeat_and_remesh():
    mon = FT.HeartbeatMonitor(8, interval_s=1.0, dead_after=2)
    for h in range(8):
        mon.beat(h, t=100.0)
    assert mon.sweep(now=101.0) == []
    for h in range(7):
        mon.beat(h, t=104.0)
    dead = mon.sweep(now=104.5)
    assert dead == [7]
    plan = FT.plan_remesh(7 * 4, model_parallel=4)
    assert plan.mesh_shape == (7, 4)
    with pytest.raises(RuntimeError):
        FT.plan_remesh(3, model_parallel=4)


def test_straggler_detection_and_eviction():
    det = FT.StragglerDetector(window=8, threshold=3.0, evict_after=3)
    evicted = []
    for step in range(6):
        for h in range(6):
            det.record(h, 1.0 + (2.0 if h == 5 else 0.0)
                       + 0.01 * np.random.default_rng(step * 7 + h).random())
        strag, evict = det.classify()
        evicted.extend(evict)
        if step >= 2:
            assert 5 in strag
    assert 5 in evicted


def test_fault_tolerant_runner_elastic_restart():
    r = FT.FaultTolerantRunner(n_hosts=8, model_parallel=4, chips_per_host=4)
    times = {h: 1.0 for h in range(8)}
    r.on_step(0, times, now=100.0)
    # host 3 stops beating
    times2 = {h: 1.0 for h in range(8) if h != 3}
    with pytest.raises(FT.FaultTolerantRunner.ElasticRestart) as ei:
        for i in range(1, 10):
            r.on_step(i, times2, now=100.0 + 40 * i)
    plan = ei.value.plan
    assert 3 in plan.dropped_hosts
    assert plan.mesh_shape[0] * plan.mesh_shape[1] <= 28
