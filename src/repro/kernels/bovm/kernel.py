"""Pallas TPU kernels for the DAWN sweep (the paper's compute hot spot).

Two kernels, matching the paper's two directions:

``fused_sweep_kernel`` — push direction (paper Alg. 1 as batched GEMM).
  Grid (Si, Nj, Kk), K innermost.  Each (i, j) output tile accumulates
  frontier-block × adjacency-block products on the MXU, then fuses the
  DAWN epilogue (hit test + Thm 3.2 visited-skip + distance write).
  The paper's per-element early exit becomes tile skipping driven by two
  scalar-prefetched occupancy tables:
    * f_occ[i, k]  — frontier block (i, k) has any active source
                     (input sparsity: late sweeps have tiny frontiers);
    * o_occ[i, j]  — output tile (i, j) has any unreached target
                     (output sparsity: early tiles retire as distances fill —
                     exactly Thm 3.2 "skip discovered targets" at tile rank).
  A skipped (i, j, k) step performs no MXU work and no VMEM traffic beyond
  the (already scheduled) block fetches.

``packed_pull_kernel`` — pull direction (paper's CSC BOVM, §3.2), bit-packed.
  hits[s, j] = OR_w(frontier[s, w] & in_nbrs[j, w]) over uint32 words:
  32 nodes/byte-lane, pure VPU bitwise ops — the TPU analogue of the
  boolean-compression argument in Eq. 3/4.

VMEM budgets (defaults): push tiles (128×512 f + 512×128 a + 128×128 acc/out)
≈ 0.6 MB;  pull tiles (128×W_blk + 128×W_blk uint32 + 128×128 acc) ≲ 1 MB.
All matmul dims are multiples of 128 (MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import common


# --------------------------------------------------------------------------
# push direction: fused masked GEMM sweep
# --------------------------------------------------------------------------

def _fused_sweep_kernel(f_occ_ref, o_occ_ref, step_ref,        # scalar prefetch
                        f_ref, a_ref, dist_ref,                # VMEM in
                        new_ref, dist_out_ref,                 # VMEM out
                        acc_ref):                              # VMEM scratch
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    live = (f_occ_ref[i, k] > 0) & (o_occ_ref[i, j] > 0)

    @pl.when(live)
    def _accumulate():
        acc_ref[...] += jnp.dot(
            f_ref[...].astype(jnp.float32), a_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        dist = dist_ref[...]
        new = (acc_ref[...] > 0) & (dist < 0)
        new_ref[...] = new.astype(jnp.int8)
        dist_out_ref[...] = jnp.where(new, step_ref[0], dist)


@functools.partial(jax.jit, static_argnames=("bs", "bn", "bk", "interpret"))
def fused_sweep(frontier: jax.Array, adj: jax.Array, dist: jax.Array,
                step: jax.Array, *, bs: int = 128, bn: int = 128,
                bk: int = 512, interpret: bool = False):
    """One fused DAWN sweep. Shapes: frontier (S,k) int8, adj (k,n) int8,
    dist (S,n) int32; S % bs == 0, n % bn == 0, k % bk == 0.  The square
    single-device operand has k == n; the sharded executor dispatches a
    K-row block (k = n/C) and OR-combines the partial across shards."""
    s, k = frontier.shape
    ka, n = adj.shape
    assert ka == k and dist.shape == (s, n), \
        (frontier.shape, adj.shape, dist.shape)
    common.check_push_tiles(s, n, bs, bn, bk, k=k)
    gi, gj, gk = s // bs, n // bn, k // bk

    # occupancy tables (computed by XLA; cheap VPU reproductions per sweep)
    f_occ = common.block_any(frontier != 0, gi, bs, gk, bk)
    o_occ = common.block_any(dist < 0, gi, bs, gj, bn)
    step_arr = jnp.asarray(step, jnp.int32).reshape(1)

    grid_spec = common.push_grid_spec(gi, gj, gk, bs=bs, bn=bn, bk=bk,
                                      num_scalar_prefetch=3,
                                      acc_dtype=jnp.float32)
    new, dist_out = pl.pallas_call(
        _fused_sweep_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((s, n), jnp.int8),
                   jax.ShapeDtypeStruct((s, n), jnp.int32)],
        compiler_params=common.sweep_compiler_params(),
        interpret=interpret,
    )(f_occ.astype(jnp.int32), o_occ.astype(jnp.int32), step_arr,
      frontier, adj, dist)
    return new, dist_out


# --------------------------------------------------------------------------
# pull direction: bit-packed AND/OR sweep (VPU)
# --------------------------------------------------------------------------

def _packed_pull_kernel(step_ref,                 # scalar prefetch
                        f_ref, at_ref, dist_ref,  # VMEM in
                        new_ref, dist_out_ref,    # VMEM out
                        acc_ref):                 # VMEM scratch (bs, bn) int32
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    f = f_ref[...]       # (bs, wk) uint32
    at = at_ref[...]     # (bn, wk) uint32

    def word(w, acc):
        fw = jax.lax.dynamic_slice_in_dim(f, w, 1, 1)    # (bs, 1)
        aw = jax.lax.dynamic_slice_in_dim(at, w, 1, 1)   # (bn, 1)
        pair = fw & aw.reshape(1, -1)                    # (bs, bn) uint32
        return acc | (pair != 0).astype(jnp.int32)

    acc_ref[...] = jax.lax.fori_loop(0, f.shape[1], word, acc_ref[...])

    @pl.when(k == nk - 1)
    def _epilogue():
        dist = dist_ref[...]
        new = (acc_ref[...] > 0) & (dist < 0)
        new_ref[...] = new.astype(jnp.int8)
        dist_out_ref[...] = jnp.where(new, step_ref[0], dist)


@functools.partial(jax.jit, static_argnames=("bs", "bn", "wk", "interpret"))
def packed_pull_sweep(frontier_packed: jax.Array, adj_in_packed: jax.Array,
                      dist: jax.Array, step: jax.Array, *, bs: int = 8,
                      bn: int = 128, wk: int = 128, interpret: bool = False):
    """Bit-packed pull sweep.  frontier_packed (S, W) uint32,
    adj_in_packed (n, W) uint32 (row j = packed in-neighbours of j),
    dist (S, n) int32.  S % bs == 0, n % bn == 0, W % wk == 0."""
    s, w = frontier_packed.shape
    n = adj_in_packed.shape[0]
    assert adj_in_packed.shape == (n, w) and dist.shape == (s, n)
    assert s % bs == 0 and n % bn == 0 and w % wk == 0, (s, n, w, bs, bn, wk)
    gi, gj, gk = s // bs, n // bn, w // wk
    step_arr = jnp.asarray(step, jnp.int32).reshape(1)

    grid_spec = common.pull_grid_spec(gi, gj, gk, bs=bs, bn=bn, wk=wk,
                                      num_scalar_prefetch=1,
                                      acc_dtype=jnp.int32)
    new, dist_out = pl.pallas_call(
        _packed_pull_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((s, n), jnp.int8),
                   jax.ShapeDtypeStruct((s, n), jnp.int32)],
        compiler_params=common.sweep_compiler_params(),
        interpret=interpret,
    )(step_arr, frontier_packed, adj_in_packed, dist)
    return new, dist_out
