"""Serving engine: continuous batching must match offline greedy decode."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.serve import Request, ServingEngine

CFG = T.LMConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                 d_head=16, d_ff=128, vocab=96)


def _offline(params, prompt, max_new):
    toks = list(prompt)
    for _ in range(max_new):
        lg = T.forward(params, jnp.asarray([toks]), CFG)
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_offline_greedy():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    eng = ServingEngine(params, CFG, slots=2, max_len=64)
    reqs = []
    for r in range(5):
        prompt = (np.arange(3 + 2 * r) * 7 + r) % CFG.vocab
        reqs.append(Request(rid=r, prompt=prompt.astype(np.int32),
                            max_new=3 + (r % 3)))
        eng.submit(reqs[-1])
    done = eng.run_to_completion()
    assert len(done) == 5
    for d in done:
        assert d.out == _offline(params, d.prompt, d.max_new)


def test_slot_reuse_and_latency_fields():
    params = T.init_params(jax.random.PRNGKey(1), CFG)
    eng = ServingEngine(params, CFG, slots=1, max_len=64)
    for r in range(3):
        eng.submit(Request(rid=r, prompt=np.array([1, 2, 3], np.int32),
                           max_new=2))
    done = eng.run_to_completion()
    assert len(done) == 3
    for d in done:
        assert d.t_done >= d.t_first >= d.t_submit


def test_decode_active_mask_freezes_rows():
    params = T.init_params(jax.random.PRNGKey(2), CFG)
    cache = T.make_cache(CFG, 2, 8)
    toks = jnp.asarray([[5], [9]])
    active = jnp.asarray([True, False])
    _, cache = T.decode_step(params, cache, toks, CFG, active=active)
    assert int(cache["pos"][0]) == 1
    assert int(cache["pos"][1]) == 0
    assert float(jnp.abs(cache["k"][:, 1].astype(jnp.float32)).sum()) == 0.0


def test_graph_service_routes_large_flushes_to_sharded_path():
    """With a mesh configured, micro-batches at/above the threshold run
    through the sharded executor; results stay oracle-exact and small
    flushes stay on the single-device path."""
    from oracles import bfs_dist, dijkstra_dist
    from repro.graph import generators as gen
    from repro.launch.mesh import make_mesh
    from repro.serve import GraphQuery, GraphService

    mesh = make_mesh((1, 1), ("data", "model"))
    g = gen.watts_strogatz(96, 6, 0.1, seed=3)
    w = np.random.default_rng(0).uniform(0.5, 3.0, g.m_pad).astype(
        np.float32)
    svc = GraphService(g, weights=w, max_batch=16, mesh=mesh,
                       sharded_threshold=4)
    for i in range(5):
        svc.submit(GraphQuery(qid=i, source=i,
                              target=None if i % 2 else 90))
    for i in range(5, 10):
        svc.submit(GraphQuery(qid=i, source=i, weighted=True,
                              target=None if i % 2 else 90))
    served = svc.flush()
    assert len(served) == 10 and svc.sharded_flushes == 2
    for q in served:
        ref = dijkstra_dist(g, w, q.source) if q.weighted \
            else bfs_dist(g, q.source)
        if q.target is not None:
            got = q.cost if q.weighted else q.hops
            np.testing.assert_allclose(got, ref[q.target], rtol=1e-6)
        elif q.weighted:
            np.testing.assert_allclose(q.dist, ref, rtol=1e-6)
        else:
            np.testing.assert_array_equal(q.dist, ref)

    # under the threshold the single-device path serves the flush
    svc2 = GraphService(g, max_batch=16, mesh=mesh, sharded_threshold=8)
    for i in range(3):
        svc2.submit(GraphQuery(qid=i, source=i))
    svc2.flush()
    assert svc2.sharded_flushes == 0


def test_graph_service_serves_analytics_queries():
    """GraphQuery(analytics=...) joins the continuous-batching loop:
    per-source measures micro-batch into one centrality run per flush;
    betweenness is computed once, cached, and matches the independent
    Brandes oracle."""
    from oracles import (bfs_dist, brandes_betweenness,
                         closeness_centrality, eccentricities,
                         harmonic_centrality)
    from repro.graph import generators as gen
    from repro.serve import GraphQuery, GraphService

    g = gen.watts_strogatz(96, 6, 0.1, seed=5)
    svc = GraphService(g, max_batch=16)
    for i in range(5):
        svc.submit(GraphQuery(qid=i, source=i,
                              analytics=("closeness", "harmonic",
                                         "eccentricity")))
    svc.submit(GraphQuery(qid=5, source=7, analytics=("betweenness",)))
    svc.submit(GraphQuery(qid=6, source=3))       # distance query rides along
    served = svc.flush()
    assert len(served) == 7 and svc.pending() == 0
    bc_ref = brandes_betweenness(g)
    for q in served:
        if q.analytics is None:
            np.testing.assert_array_equal(q.dist, bfs_dist(g, q.source))
            continue
        src = np.asarray([q.source])
        if "betweenness" in q.analytics:
            np.testing.assert_allclose(q.analytics_result["betweenness"],
                                       bc_ref[q.source], rtol=1e-4,
                                       atol=1e-6)
        else:
            np.testing.assert_allclose(
                q.analytics_result["closeness"],
                closeness_centrality(g, src)[0], rtol=1e-9)
            np.testing.assert_allclose(
                q.analytics_result["harmonic"],
                harmonic_centrality(g, src)[0], rtol=1e-5)
            assert q.analytics_result["eccentricity"] == \
                int(eccentricities(g, src)[0])
    # the whole-graph betweenness vector is cached across flushes
    assert svc._betweenness is not None
    svc.submit(GraphQuery(qid=9, source=11, analytics=("betweenness",)))
    (q,) = svc.flush()
    np.testing.assert_allclose(q.analytics_result["betweenness"],
                               bc_ref[11], rtol=1e-4, atol=1e-6)


def test_graph_service_rejects_bad_analytics():
    import pytest
    from repro.graph import generators as gen
    from repro.serve import GraphQuery, GraphService

    g = gen.grid2d(6, 6)
    svc = GraphService(g, max_batch=8)
    with pytest.raises(ValueError, match="unknown analytics"):
        svc.submit(GraphQuery(qid=0, source=0, analytics=("pagerank",)))
    with pytest.raises(ValueError, match="unweighted"):
        svc.submit(GraphQuery(qid=1, source=0, weighted=True,
                              analytics=("closeness",)))
