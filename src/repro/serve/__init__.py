"""Graph-query serving tier: tiered admission + bucketed micro-batching.

The LM serving engine (``Request`` / ``ServingEngine``) moved to
``repro._attic.lm_serving`` with the rest of the model zoo; importing
those names from here still works but emits a :class:`DeprecationWarning`
(once per process per name).
"""
import warnings

from .engine import GraphQuery, GraphService
from .oracle import (DistanceOracle, OracleAnswer, build_landmark_labels,
                     select_top_k)

__all__ = ["GraphQuery", "GraphService",
           "DistanceOracle", "OracleAnswer", "build_landmark_labels",
           "select_top_k"]

_ATTIC_NAMES = ("Request", "ServingEngine")
_warned = set()


def __getattr__(name):
    if name in _ATTIC_NAMES:
        if name not in _warned:
            _warned.add(name)
            warnings.warn(
                f"repro.serve.{name} moved to repro._attic.lm_serving "
                "(seed-era LM serving stack, quarantined per ROADMAP "
                "item 3); import it from there",
                DeprecationWarning, stacklevel=2)
        from repro._attic import lm_serving
        return getattr(lm_serving, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
