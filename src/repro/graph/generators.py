"""Synthetic graph families matched to the paper's evaluation suite.

SuiteSparse / Gunrock datasets are not available offline, so the benchmark
suite reproduces the paper's graph *families* instead:

  - ``grid2d``        road-network-like: high diameter, degree ≤ 4
  - ``rmat``          scale-free / social-network-like (Graph500 RMAT)
  - ``watts_strogatz``small-world: low diameter, high clustering
                      (the paper's citation/collaboration regime, §4.3)
  - ``erdos_renyi``   uniform random
  - ``ba``            preferential attachment (web-like)
  - ``disconnected``  many WCCs — exercises the O(E_wcc) claims
  - ``mycielskian``   dense low-diameter (paper's mycielskian16 case)

All generators are deterministic in ``seed`` and return host numpy COO,
which callers feed to :class:`repro.graph.csr.CSRGraph`.
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph, symmetrize


def erdos_renyi(n: int, avg_degree: float, *, seed: int = 0,
                directed: bool = True) -> CSRGraph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    if not directed:
        src, dst = symmetrize(src, dst)
    return CSRGraph.from_edges(src, dst, n)


def grid2d(rows: int, cols: int, *, seed: int = 0) -> CSRGraph:
    """4-connected grid — road-network stand-in (diameter rows+cols)."""
    del seed
    r, c = np.meshgrid(np.arange(rows), np.arange(cols), indexing="ij")
    vid = (r * cols + c).ravel()
    src, dst = [], []
    right = vid.reshape(rows, cols)[:, :-1].ravel()
    src.append(right); dst.append(right + 1)
    down = vid.reshape(rows, cols)[:-1, :].ravel()
    src.append(down); dst.append(down + cols)
    src = np.concatenate(src); dst = np.concatenate(dst)
    src, dst = symmetrize(src, dst)
    return CSRGraph.from_edges(src, dst, rows * cols)


def rmat(scale: int, edge_factor: int = 16, *, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19,
         directed: bool = True) -> CSRGraph:
    """Graph500-style RMAT: scale-free, power-law degrees."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        u = rng.random(m)
        v = rng.random(m)
        src_bit = u > (a + b)
        dst_bit = np.where(src_bit, v > (c / (c + (1 - a - b - c))),
                           v > (a / (a + b)))
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    if not directed:
        src, dst = symmetrize(src, dst)
    return CSRGraph.from_edges(src, dst, n)


def watts_strogatz(n: int, k: int = 6, p: float = 0.1, *,
                   seed: int = 0) -> CSRGraph:
    """Small-world ring lattice with rewiring — paper's low-ε regime."""
    rng = np.random.default_rng(seed)
    base = np.arange(n)
    src, dst = [], []
    for off in range(1, k // 2 + 1):
        s = base
        d = (base + off) % n
        rewire = rng.random(n) < p
        d = np.where(rewire, rng.integers(0, n, size=n), d)
        src.append(s); dst.append(d)
    src = np.concatenate(src); dst = np.concatenate(dst)
    src, dst = symmetrize(src, dst)
    return CSRGraph.from_edges(src, dst, n)


def barabasi_albert(n: int, m_attach: int = 4, *, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    targets = list(range(m_attach))
    repeated: list[int] = list(range(m_attach))
    src, dst = [], []
    for v in range(m_attach, n):
        picks = rng.choice(repeated, size=m_attach, replace=False) \
            if len(set(repeated)) >= m_attach else list(targets)[:m_attach]
        for t in np.atleast_1d(picks):
            src.append(v); dst.append(int(t))
            repeated.extend([v, int(t)])
    src = np.asarray(src); dst = np.asarray(dst)
    src, dst = symmetrize(src, dst)
    return CSRGraph.from_edges(src, dst, n)


def disconnected(n_components: int, comp_size: int, avg_degree: float = 4.0,
                 *, seed: int = 0) -> CSRGraph:
    """Union of ER components + isolated nodes — non-connected-graph regime
    where DAWN's O(E_wcc(i)) beats global-m bounds (paper §3.3)."""
    rng = np.random.default_rng(seed)
    src, dst = [], []
    for ci in range(n_components):
        base = ci * comp_size
        size = max(2, comp_size - (ci % 3))  # slightly ragged components
        mi = int(size * avg_degree)
        s = rng.integers(0, size, size=mi) + base
        d = rng.integers(0, size, size=mi) + base
        src.append(s); dst.append(d)
    n = n_components * comp_size + 8  # + isolated nodes
    src = np.concatenate(src); dst = np.concatenate(dst)
    src, dst = symmetrize(src, dst)
    return CSRGraph.from_edges(src, dst, n)


def mycielskian(k: int) -> CSRGraph:
    """Mycielskian iteration from K2 — dense, diameter 2 at high k.
    Node count 3·2^(k-2) - 1; we cap k ≤ 12 for test budgets."""
    src = np.array([0]); dst = np.array([1])
    n = 2
    for _ in range(max(0, k - 2)):
        # nodes: originals [0,n), shadows [n,2n), apex 2n
        s2 = np.concatenate([src, src, dst + n])
        d2 = np.concatenate([dst, dst + n, src])
        apex_s = np.arange(n, 2 * n)
        s2 = np.concatenate([s2, apex_s])
        d2 = np.concatenate([d2, np.full(n, 2 * n)])
        src, dst, n = s2, d2, 2 * n + 1
    src, dst = symmetrize(src, dst)
    return CSRGraph.from_edges(src, dst, n)


def bipartite_sessions(n_users: int, n_items: int, clicks_per_user: int, *,
                       seed: int = 0) -> CSRGraph:
    """User→item click graph (recsys candidate-expansion example)."""
    rng = np.random.default_rng(seed)
    users = np.repeat(np.arange(n_users), clicks_per_user)
    # zipf-ish item popularity
    items = (rng.zipf(1.3, size=len(users)) % n_items) + n_users
    src, dst = symmetrize(users, items)
    return CSRGraph.from_edges(src, dst, n_users + n_items)


SUITE = {
    "grid_road_sm": lambda: grid2d(64, 64),
    "grid_road_md": lambda: grid2d(180, 180),
    "rmat_social_sm": lambda: rmat(10, 8, directed=False, seed=1),
    "rmat_social_md": lambda: rmat(13, 12, directed=False, seed=2),
    "ws_citation_sm": lambda: watts_strogatz(4096, 8, 0.05, seed=3),
    "ws_citation_md": lambda: watts_strogatz(20000, 10, 0.08, seed=4),
    "er_uniform_sm": lambda: erdos_renyi(4096, 6.0, directed=False, seed=5),
    "ba_web_sm": lambda: barabasi_albert(4096, 4, seed=6),
    "disconnected_sm": lambda: disconnected(24, 160, 4.0, seed=7),
    "mycielskian10": lambda: mycielskian(10),
}
