"""Optimizers built from scratch (no optax): AdamW + Adafactor.

AdamW keeps f32 first/second moments (sharded like the params → ZeRO-style
when params are FSDP-sharded).  Adafactor keeps factored second moments
(row/col statistics) — the low-memory choice used for the giant MoE archs
(DESIGN.md §6): state is ~(d_in + d_out) per matrix instead of d_in·d_out.

API:
    opt   = adamw(peak_lr=3e-4, ...)
    state = opt.init(params)
    new_params, new_state, stats = opt.update(params, grads, state)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


def cosine_schedule(peak_lr: float, warmup: int = 100,
                    total: int = 10_000, floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, peak_lr * cos)
    return lr


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


class Optimizer(NamedTuple):
    init: Callable
    update: Callable
    state_specs: Callable  # param_specs pytree -> state specs pytree


def adamw(peak_lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          max_grad_norm: float = 1.0,
          schedule: Optional[Callable] = None) -> Optimizer:
    lr_fn = schedule or cosine_schedule(peak_lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, grads, state):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        step = state["step"] + 1
        lr = lr_fn(step)
        b1c = 1 - b1 ** step.astype(jnp.float32)
        b2c = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            u = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        g_leaves = treedef.flatten_up_to(grads)
        m_leaves = treedef.flatten_up_to(state["m"])
        v_leaves = treedef.flatten_up_to(state["v"])
        res = [upd(p, g, m, v) for p, g, m, v
               in zip(p_leaves, g_leaves, m_leaves, v_leaves)]
        new_p = treedef.unflatten([r[0] for r in res])
        new_m = treedef.unflatten([r[1] for r in res])
        new_v = treedef.unflatten([r[2] for r in res])
        return new_p, {"m": new_m, "v": new_v, "step": step}, \
            {"lr": lr, "grad_norm": gnorm}

    def state_specs(param_specs):
        return {"m": param_specs, "v": param_specs,
                "step": jax.sharding.PartitionSpec()}

    return Optimizer(init, update, state_specs)


def adafactor(peak_lr: float = 1e-3, decay: float = 0.8,
              eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0,
              schedule: Optional[Callable] = None) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern, 2018)."""
    lr_fn = schedule or cosine_schedule(peak_lr)

    def init(params):
        def stat(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"stats": jax.tree.map(stat, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, grads, state):
        step = state["step"] + 1
        lr = lr_fn(step)
        beta = 1.0 - (step.astype(jnp.float32) + 1) ** -decay

        def upd_core(p, g, s):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)
                prec = (vr[..., None] / denom[..., None]) * vc[..., None, :]
                u = g32 * jax.lax.rsqrt(jnp.maximum(prec, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g32 * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_s

        def upd(p, g, s):
            # stacked (L, ...) leaves update layer-by-layer: the transient
            # f32 copies of a 218B-param expert stack don't fit otherwise
            # (13.6 GB → ~0.4 GB on deepseek-v3, §Perf)
            if p.ndim >= 3:
                return jax.lax.map(lambda a: upd_core(*a), (p, g, s))
            return upd_core(p, g, s)

        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        g_leaves = treedef.flatten_up_to(grads)
        s_leaves = treedef.flatten_up_to(state["stats"])
        res = [upd(p, g, s)
               for p, g, s in zip(p_leaves, g_leaves, s_leaves)]
        new_p = treedef.unflatten([r[0] for r in res])
        new_stats = treedef.unflatten([r[1] for r in res])
        return new_p, {"stats": new_stats, "step": step}, {"lr": lr}

    def state_specs(param_specs):
        from jax.sharding import PartitionSpec as P

        def stat_spec(spec):
            parts = tuple(spec) if spec else ()
            if len(parts) >= 2:
                return {"vr": P(*parts[:-1]),
                        "vc": P(*(parts[:-2] + parts[-1:]))}
            return {"v": spec}

        return {"stats": jax.tree.map(
                    stat_spec, param_specs,
                    is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
                "step": jax.sharding.PartitionSpec()}

    return Optimizer(init, update, state_specs)


def sgd(lr: float = 1e-2) -> Optimizer:
    """Plain SGD (tests / tiny examples)."""
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(params, grads, state):
        new_p = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return new_p, {"step": state["step"] + 1}, {}

    def state_specs(param_specs):
        return {"step": jax.sharding.PartitionSpec()}

    return Optimizer(init, update, state_specs)
