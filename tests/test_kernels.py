"""Pallas kernel validation (interpret=True): the semiring kernel registry,
shape/dtype sweeps + full BFS drivers vs the pure-jnp oracles for the
boolean kernels, and the tropical min-plus kernels vs their oracles, the
dense reference forms, and scipy Dijkstra.

This module runs without hypothesis (only the property-based test is
guarded) so CI can execute it as its own fast kernel-layer job step.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property test skips; everything else runs
    HAVE_HYPOTHESIS = False

from repro.graph import generators as gen
from repro.core import (WeightedConfig, bfs_queue_numpy, dijkstra_oracle,
                        pack_bits, weighted_apsp)
from repro.kernels import common, registry
from repro.kernels.bovm import (fused_sweep, packed_pull_sweep, sweep_ref,
                                packed_pull_ref, msbfs_kernel, msbfs_packed,
                                pack_adjacency_pull)
from repro.kernels.tropical import (fused_minplus_sweep, sparse_relax_sweep,
                                    minplus_sweep_ref, sparse_relax_ref)


def _random_state(rng, s, n, density=0.05, visited=0.2):
    f = (rng.random((s, n)) < density).astype(np.int8)
    dist = np.where(rng.random((s, n)) < visited, 1, -1).astype(np.int32)
    return jnp.asarray(f), jnp.asarray(dist)


# --------------------------------------------------------------------------
# the registry: one substrate, N semirings
# --------------------------------------------------------------------------

def test_registry_has_both_semirings():
    assert registry.available() == ("boolean", "tropical")
    assert registry.has("boolean") and registry.has("tropical")
    assert set(registry.get("boolean").forms) == {"push", "pull"}
    assert set(registry.get("tropical").forms) == {"dense", "sparse"}


def test_registry_accepts_semiring_objects():
    from repro.core import BOOLEAN, TROPICAL
    assert registry.get(BOOLEAN).forms["push"] is fused_sweep
    assert registry.get(TROPICAL).forms["dense"] is fused_minplus_sweep
    with pytest.raises(KeyError, match="min_label"):
        registry.get("min_label")    # no kernels for label propagation


def test_vmem_budgets_under_per_core_limit():
    """Every registered kernel's default tiles sit well under ~16 MB."""
    assert registry.get("boolean").vmem_bytes(form="push") \
        < common.VMEM_BUDGET_BYTES // 4
    assert registry.get("boolean").vmem_bytes(form="pull") \
        < common.VMEM_BUDGET_BYTES // 4
    assert registry.get("tropical").vmem_bytes(form="dense") \
        < common.VMEM_BUDGET_BYTES // 4
    assert registry.get("tropical").vmem_bytes(form="sparse", s=128,
                                               n_pad=2048) \
        < common.VMEM_BUDGET_BYTES // 4


# --------------------------------------------------------------------------
# boolean semiring kernels (paper Algs. 1/2)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("s,n,bs,bn,bk", [
    (64, 256, 64, 128, 128),
    (128, 512, 128, 128, 256),
    (8, 128, 8, 128, 128),
    (256, 384, 64, 128, 128),
])
def test_fused_sweep_shapes(s, n, bs, bn, bk):
    rng = np.random.default_rng(s * n)
    g = gen.erdos_renyi(n, 4.0, seed=n, directed=False)
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    f, dist = _random_state(rng, s, n)
    new_k, dist_k = fused_sweep(f, adj, dist, 5, bs=bs, bn=bn, bk=bk,
                                interpret=True)
    new_r, dist_r = sweep_ref(f, adj, dist, 5)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


@pytest.mark.parametrize("s,n,bs,bn,wk", [
    (8, 256, 8, 128, 8),
    (16, 512, 8, 128, 16),
    (32, 128, 16, 128, 4),
])
def test_packed_pull_shapes(s, n, bs, bn, wk):
    rng = np.random.default_rng(s + n)
    g = gen.erdos_renyi(n, 5.0, seed=n + 1, directed=True)
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    ap = pack_adjacency_pull(adj)
    f, dist = _random_state(rng, s, n)
    fp = pack_bits(f > 0)
    new_k, dist_k = packed_pull_sweep(fp, ap, dist, 3, bs=bs, bn=bn, wk=wk,
                                      interpret=True)
    new_r, dist_r = packed_pull_ref(fp, ap, dist, 3)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), density=st.floats(0.0, 0.3),
           visited=st.floats(0.0, 1.0))
    def test_fused_sweep_property(seed, density, visited):
        """Property: kernel == oracle for arbitrary frontier/visited
        states."""
        rng = np.random.default_rng(seed)
        n, s = 256, 64
        adj = jnp.asarray((rng.random((n, n)) < 0.02).astype(np.int8))
        f = jnp.asarray((rng.random((s, n)) < density).astype(np.int8))
        dist = jnp.asarray(
            np.where(rng.random((s, n)) < visited, 2, -1).astype(np.int32))
        new_k, dist_k = fused_sweep(f, adj, dist, 7, bs=64, bn=128, bk=128,
                                    interpret=True)
        new_r, dist_r = sweep_ref(f, adj, dist, 7)
        np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
        np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fused_sweep_property():
        """Stub so the missing property coverage shows up as a skip."""


def test_msbfs_kernel_end_to_end():
    g = gen.rmat(8, 5, directed=False, seed=21)
    n = 256
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    srcs = jnp.arange(64, dtype=jnp.int32)
    res = msbfs_kernel(adj, srcs, max_steps=n, interpret=True,
                       bs=64, bn=128, bk=128)
    refs = np.stack([bfs_queue_numpy(g, int(x)) for x in np.asarray(srcs)])
    np.testing.assert_array_equal(
        np.asarray(res.dist)[:, :g.n_nodes], refs)


def test_msbfs_packed_end_to_end():
    g = gen.rmat(8, 5, directed=True, seed=22)
    n = 256
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n)), jnp.int8)
    ap = pack_adjacency_pull(adj)
    srcs = jnp.arange(16, dtype=jnp.int32)
    res = msbfs_packed(ap, srcs, n, max_steps=n, interpret=True,
                       bs=8, bn=128, wk=8)
    refs = np.stack([bfs_queue_numpy(g, int(x)) for x in np.asarray(srcs)])
    np.testing.assert_array_equal(
        np.asarray(res.dist)[:, :g.n_nodes], refs)


def test_tile_skip_preserves_semantics():
    """All-visited output tiles and empty frontier tiles must not change
    results (the Thm 3.2 tile-skip)."""
    rng = np.random.default_rng(0)
    n, s = 256, 64
    adj = jnp.asarray((rng.random((n, n)) < 0.05).astype(np.int8))
    f = np.zeros((s, n), np.int8)
    f[:, :128] = (rng.random((s, 128)) < 0.1)   # half the k-tiles empty
    dist = np.full((s, n), -1, np.int32)
    dist[:, 128:] = 3                            # half the out-tiles visited
    new_k, dist_k = fused_sweep(jnp.asarray(f), adj, jnp.asarray(dist), 4,
                                bs=64, bn=128, bk=128, interpret=True)
    new_r, dist_r = sweep_ref(jnp.asarray(f), adj, jnp.asarray(dist), 4)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


# --------------------------------------------------------------------------
# tropical semiring kernels (paper §5, min-plus)
# --------------------------------------------------------------------------

def _random_tropical_state(rng, s, n, *, density=0.03, wdensity=0.03):
    w = np.full((n, n), np.inf, np.float32)
    mask = rng.random((n, n)) < wdensity
    w[mask] = rng.uniform(0.5, 4.0, mask.sum())
    dist = np.where(rng.random((s, n)) < 0.3,
                    rng.uniform(0.0, 10.0, (s, n)), np.inf).astype(np.float32)
    f = (rng.random((s, n)) < density).astype(np.int8)
    fdist = np.where(f != 0, dist, np.inf).astype(np.float32)
    finite = w[np.isfinite(w)]
    w_min = np.float32(finite.min() if finite.size else np.inf)
    return (jnp.asarray(f), jnp.asarray(fdist), jnp.asarray(w),
            jnp.asarray(dist), w_min)


@pytest.mark.parametrize("s,n,bs,bn,bk", [
    (64, 256, 64, 128, 128),
    (8, 128, 8, 128, 128),
    (16, 384, 16, 128, 128),
])
def test_minplus_sweep_shapes(s, n, bs, bn, bk):
    rng = np.random.default_rng(s * n + 1)
    _, fdist, w, dist, w_min = _random_tropical_state(rng, s, n)
    new_k, dist_k = fused_minplus_sweep(fdist, w, dist, w_min, bs=bs, bn=bn,
                                        bk=bk, interpret=True)
    new_r, dist_r = minplus_sweep_ref(fdist, w, dist)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


def test_minplus_settled_skip_preserves_semantics():
    """The tropical o_occ table (Dijkstra settled bound at tile rank) must
    be exact: tiles whose distances all sit under min_frontier + w_min are
    skipped, and the result still matches the unskipped oracle."""
    rng = np.random.default_rng(7)
    s, n = 64, 256
    w = np.full((n, n), np.inf, np.float32)
    mask = rng.random((n, n)) < 0.05
    w[mask] = rng.uniform(1.0, 2.0, mask.sum())
    dist = np.full((s, n), np.inf, np.float32)
    dist[:, :128] = rng.uniform(0.0, 0.5, (s, 128))    # settled out-tile
    f = np.zeros((s, n), np.int8)
    f[:, :64] = (rng.random((s, 64)) < 0.2)            # half the k-tiles dead
    fdist = np.where(f != 0, dist, np.inf).astype(np.float32)
    w_min = np.float32(w[np.isfinite(w)].min())
    new_k, dist_k = fused_minplus_sweep(
        jnp.asarray(fdist), jnp.asarray(w), jnp.asarray(dist), w_min,
        bs=64, bn=128, bk=128, interpret=True)
    new_r, dist_r = minplus_sweep_ref(jnp.asarray(fdist), jnp.asarray(w),
                                      jnp.asarray(dist))
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


@pytest.mark.parametrize("s,n_pad,eb", [(8, 128, 128), (16, 256, 128),
                                        (32, 256, 256)])
def test_sparse_relax_shapes(s, n_pad, eb):
    rng = np.random.default_rng(s + n_pad)
    n = n_pad - 1                                     # room for the sentinel
    m = 4 * n
    m_pad = ((m + eb - 1) // eb) * eb
    src = np.full(m_pad, n, np.int32)
    dst = np.full(m_pad, n, np.int32)
    w = np.full(m_pad, np.inf, np.float32)
    src[:m] = rng.integers(0, n, m)
    dst[:m] = rng.integers(0, n, m)
    w[:m] = rng.uniform(0.5, 4.0, m)
    f = (rng.random((s, n_pad)) < 0.1).astype(np.int8)
    dist = np.where(rng.random((s, n_pad)) < 0.4,
                    rng.uniform(0.0, 8.0, (s, n_pad)),
                    np.inf).astype(np.float32)
    args = (jnp.asarray(f), jnp.asarray(dist), jnp.asarray(src),
            jnp.asarray(dst), jnp.asarray(w))
    new_k, dist_k = sparse_relax_sweep(*args, eb=eb, interpret=True)
    new_r, dist_r = sparse_relax_ref(*args)
    np.testing.assert_array_equal(np.asarray(new_k), np.asarray(new_r))
    np.testing.assert_array_equal(np.asarray(dist_k), np.asarray(dist_r))


# --------------------------------------------------------------------------
# cross-semiring kernel equivalence (acceptance)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["dense", "sparse", "auto"])
def test_weighted_kernel_path_matches_dijkstra(mode, random_weighted):
    """weighted_apsp dispatching the tropical Pallas kernels under
    interpret=True == scipy Dijkstra (the PR's acceptance criterion)."""
    g, w = random_weighted(100, 3.0, 41)
    sources = np.arange(12, dtype=np.int32)
    ref = np.stack([dijkstra_oracle(g, w, int(s)) for s in sources])
    res = weighted_apsp(g, w, sources,
                        config=WeightedConfig(mode=mode, source_batch=16,
                                              use_kernel=True))
    np.testing.assert_allclose(np.asarray(res.dist), ref, rtol=1e-5)
    assert int(res.direction_counts.sum()) == int(res.sweeps) > 0


def test_weighted_kernel_matches_reference_forms(random_weighted):
    """Kernel forms and XLA reference forms are the same sweeps: identical
    distances AND identical sweep counts on the same graph."""
    g, w = random_weighted(90, 4.0, 43)
    sources = np.arange(8, dtype=np.int32)
    for mode in ("dense", "sparse"):
        kern = weighted_apsp(g, w, sources,
                             config=WeightedConfig(mode=mode, source_batch=8,
                                                   use_kernel=True))
        ref = weighted_apsp(g, w, sources,
                            config=WeightedConfig(mode=mode, source_batch=8,
                                                  use_kernel=False))
        np.testing.assert_array_equal(np.asarray(kern.dist),
                                      np.asarray(ref.dist))
        assert int(kern.sweeps) == int(ref.sweeps)


def test_unit_weight_tropical_kernel_equals_boolean_kernel():
    """(min,+) with unit weights through the tropical kernel == boolean
    BFS through the boolean kernel — the cross-semiring contract at the
    kernel layer."""
    g = gen.rmat(8, 5, directed=False, seed=51)
    n_pad = g.n_padded(128)
    w = jnp.ones((g.m_pad,), jnp.float32)
    sources = np.arange(16, dtype=np.int32)
    trop = weighted_apsp(g, np.asarray(w), sources,
                         config=WeightedConfig(mode="dense", source_batch=16,
                                               use_kernel=True))
    adj = jnp.asarray(np.asarray(g.to_dense_padded(n_pad)), jnp.int8)
    boolean = msbfs_kernel(adj, jnp.asarray(sources), max_steps=n_pad,
                           interpret=True, bs=16, bn=128, bk=128)
    bdist = np.asarray(boolean.dist)[:, :g.n_nodes].astype(np.float64)
    bdist = np.where(bdist < 0, np.inf, bdist)
    np.testing.assert_allclose(np.asarray(trop.dist), bdist)
