"""Graph batch builders for the GNN architectures.

Produces the fixed-shape batch dicts ``models/gnn.py`` expects, for all
four shape regimes (full_graph_sm / minibatch_lg / ogb_products /
molecule), plus host-side subgraph sampling on top of
``repro.graph.sampler``."""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.csr import CSRGraph
from ..graph import generators as gen


def full_graph_batch(g: CSRGraph, *, d_feat: int, n_classes: int = 41,
                     seed: int = 0, with_geometry: bool = True
                     ) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    n = g.n_nodes
    src, dst = g.edge_arrays_np()
    e_pad = g.m_pad
    src_p = np.full(e_pad, n, np.int32); src_p[:len(src)] = src
    dst_p = np.full(e_pad, n, np.int32); dst_p[:len(dst)] = dst
    batch = {
        "feat": rng.normal(size=(n, d_feat)).astype(np.float32),
        "src": src_p, "dst": dst_p,
        "labels": rng.integers(0, n_classes, n).astype(np.int32),
        "targets": rng.normal(size=(n, 2)).astype(np.float32),
        "node_mask": np.ones(n, bool),
    }
    if with_geometry:
        batch["pos"] = rng.normal(size=(n, 3)).astype(np.float32)
        batch["species"] = rng.integers(0, 50, n).astype(np.int32)
        batch["graph_id"] = np.zeros(n, np.int32)
        batch["energy"] = rng.normal(size=(1,)).astype(np.float32)
    return batch


def sampled_batch(g: CSRGraph, seeds: np.ndarray, fanouts: Sequence[int],
                  *, d_feat: int, n_classes: int = 41, seed: int = 0
                  ) -> Dict[str, np.ndarray]:
    """Fanout-sampled subgraph as a fixed-shape batch.  Node list =
    [seeds, hop1, hop2, ...]; edges connect hop h+1 → hop h (message flows
    toward the seeds).  Repeats allowed (standard GraphSAGE)."""
    from ..graph.sampler import sample_subgraph
    key = jax.random.PRNGKey(seed)
    layers = sample_subgraph(g, jnp.asarray(seeds, jnp.int32), key, fanouts)
    layers = [np.asarray(l) for l in layers]
    offsets = np.cumsum([0] + [len(l) for l in layers])
    n_sub = int(offsets[-1])
    src_l, dst_l = [], []
    for h, f in enumerate(fanouts):
        parents = np.arange(offsets[h], offsets[h + 1])
        children = np.arange(offsets[h + 1], offsets[h + 2])
        src_l.append(children)                       # child → parent
        dst_l.append(np.repeat(parents, f))
    src = np.concatenate(src_l).astype(np.int32)
    dst = np.concatenate(dst_l).astype(np.int32)
    all_nodes = np.concatenate(layers)
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, int(seeds[0])]))
    feat = rng.normal(size=(n_sub, d_feat)).astype(np.float32)
    mask = np.zeros(n_sub, bool)
    mask[: len(seeds)] = True                         # loss on seeds only
    return {
        "feat": feat, "src": src, "dst": dst,
        "labels": (all_nodes % n_classes).astype(np.int32),
        "targets": rng.normal(size=(n_sub, 2)).astype(np.float32),
        "node_mask": mask,
        "pos": rng.normal(size=(n_sub, 3)).astype(np.float32),
        "species": (all_nodes % 50).astype(np.int32),
        "graph_id": np.zeros(n_sub, np.int32),
        "energy": rng.normal(size=(1,)).astype(np.float32),
    }


def molecule_batch(*, batch: int = 128, n_nodes: int = 30, n_edges: int = 64,
                   seed: int = 0) -> Dict[str, np.ndarray]:
    """``batch`` small molecules flattened into one disjoint graph."""
    rng = np.random.default_rng(seed)
    n_tot, e_tot = batch * n_nodes, batch * n_edges
    pos = rng.normal(size=(n_tot, 3)).astype(np.float32) * 2.0
    src = np.zeros(e_tot, np.int32)
    dst = np.zeros(e_tot, np.int32)
    for b in range(batch):
        s = rng.integers(0, n_nodes, n_edges)
        d = (s + 1 + rng.integers(0, n_nodes - 1, n_edges)) % n_nodes
        src[b * n_edges:(b + 1) * n_edges] = s + b * n_nodes
        dst[b * n_edges:(b + 1) * n_edges] = d + b * n_nodes
    return {
        "feat": rng.normal(size=(n_tot, 8)).astype(np.float32),
        "pos": pos, "src": src, "dst": dst,
        "species": rng.integers(0, 20, n_tot).astype(np.int32),
        "graph_id": np.repeat(np.arange(batch), n_nodes).astype(np.int32),
        "energy": rng.normal(size=(batch,)).astype(np.float32),
        "labels": rng.integers(0, 41, n_tot).astype(np.int32),
        "targets": rng.normal(size=(n_tot, 2)).astype(np.float32),
        "node_mask": np.ones(n_tot, bool),
    }


def demo_graph(kind: str = "small", seed: int = 0) -> CSRGraph:
    if kind == "small":
        return gen.watts_strogatz(2708, 8, 0.05, seed=seed)   # Cora-sized
    if kind == "reddit":
        return gen.rmat(13, 24, directed=False, seed=seed)    # sampled-training host graph
    raise ValueError(kind)
