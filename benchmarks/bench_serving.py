"""Serving-tier load test: open-loop Poisson arrivals against the tiered
GraphService (row cache -> landmark oracle -> bucketed exact sweeps).

For each graph family the benchmark drives a seeded open-loop workload —
arrivals are scheduled ahead of time at a fixed offered rate, never
gated on completions — of point-to-point, k-nearest and full-row
queries drawn from a hot source pool, and reports:

  * ``p50_latency_us`` / ``p99_latency_us`` / ``qps`` — measured on a
    *virtual clock*: arrivals advance it to their scheduled time, and
    every submit/flush advances it by that call's measured wall time.
    Timing fields are advisory (no ``_median`` suffix — the regression
    gate does not time-gate them).
  * ``hit_rate`` — fraction of queries answered without a sweep (row
    cache + certified oracle).  **Hard-gated**: the load loop runs with
    infinite deadlines and size-threshold-only flushing, so batch
    composition — and therefore the hit counters — is a pure function
    of the seeded arrival order, independent of machine speed.
  * ``certified_count`` / ``certified_fraction`` — **hard-gated**,
    computed by replaying the query stream against a bare
    :class:`DistanceOracle` (a pure function of graph + landmarks +
    pairs; no clock anywhere).
  * ``labels_checksum`` — **hard-gated** fingerprint of the landmark
    selection + label tables.

Answers stay bit-exact by construction and this is *asserted before any
metric is reported*: every completed query of the load run is compared
against exact engine rows for its source (hops, k-nearest lists and
full rows all must match).  A second, smaller stream is then served by
an oracle-backed service and an exact-sweep-only service to fill the
advisory ``oracle_p50_beats_exact`` boolean, and a deadline mini-run
asserts expired queries are surfaced (``expired=True``) rather than
dropped.

    PYTHONPATH=src python -m benchmarks.bench_serving [--quick] [--out f.json]
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import EngineConfig, prepare_graph
from repro.core.engine import apsp_engine
from repro.graph import generators as gen
from repro.serve import DistanceOracle, GraphQuery, GraphService

FAMILIES: Dict[str, Callable] = {
    "grid_road": lambda: gen.grid2d(32, 32),
    "ws_citation": lambda: gen.watts_strogatz(1024, 8, 0.05, seed=3),
    "rmat_social": lambda: gen.rmat(10, 8, directed=False, seed=1),
    "rmat_web_directed": lambda: gen.rmat(10, 8, directed=True, seed=2),
}

QUICK_FAMILIES = ("grid_road", "ws_citation")

N_LANDMARKS = 16
POOL = 96           # hot-source pool (Zipf-weighted)
MAX_BATCH = 32
K_NEAREST = 8
OFFERED_QPS = 5000.0


class _VirtualClock:
    """Injectable clock for GraphService: arrivals set it forward to
    their scheduled instant; measured compute advances it."""

    def __init__(self):
        self.now = 0.0

    def __call__(self) -> float:
        return self.now


def _make_stream(n_queries: int, n_nodes: int, seed: int):
    """Seeded workload: (kind, source, target) triples with Zipf-hot
    sources from a fixed pool.  60% point-to-point / 20% k-nearest /
    20% full-row."""
    rng = np.random.default_rng(seed)
    pool = rng.choice(n_nodes, size=min(POOL, n_nodes), replace=False)
    w = 1.0 / np.arange(1, len(pool) + 1)          # Zipf weights
    w /= w.sum()
    sources = rng.choice(pool, size=n_queries, p=w)
    targets = rng.integers(0, n_nodes, size=n_queries)
    kinds = rng.choice(3, size=n_queries, p=[0.6, 0.2, 0.2])
    gaps = rng.exponential(1.0 / OFFERED_QPS, size=n_queries)
    arrivals = np.cumsum(gaps)
    return pool, list(zip(kinds.tolist(), sources.tolist(),
                          targets.tolist())), arrivals


def _exact_rows(pg, sources: np.ndarray) -> Dict[int, np.ndarray]:
    """Exact engine distance rows for every distinct source."""
    sources = np.unique(np.asarray(sources, np.int32))
    cfg = EngineConfig(source_batch=32)
    out: Dict[int, np.ndarray] = {}
    for i in range(0, len(sources), 32):
        chunk = sources[i:i + 32]
        dist = np.asarray(apsp_engine(pg, chunk, config=cfg).dist)
        for s, row in zip(chunk, dist):
            out[int(s)] = row
    return out


def _drive(svc: GraphService, stream, arrivals, clock: _VirtualClock
           ) -> List[GraphQuery]:
    """Open-loop load: submit at scheduled virtual instants, tick the
    deadline-aware flusher after each arrival (size-threshold-only here
    — no deadlines, no max_wait), drain the tail with flush()."""
    for i, ((kind, s, t), at) in enumerate(zip(stream, arrivals)):
        clock.now = max(clock.now, float(at))
        if kind == 0:
            q = GraphQuery(qid=i, source=s, target=t)
        elif kind == 1:
            q = GraphQuery(qid=i, source=s, k_nearest=K_NEAREST)
        else:
            q = GraphQuery(qid=i, source=s)
        t0 = time.perf_counter()
        svc.submit(q)
        clock.now += time.perf_counter() - t0
        while True:
            t0 = time.perf_counter()
            served = svc.tick()
            clock.now += time.perf_counter() - t0
            if not served:
                break
    while svc.pending():
        t0 = time.perf_counter()
        svc.flush()
        clock.now += time.perf_counter() - t0
    return svc.drain_completed()


def _assert_bit_identical(done: List[GraphQuery],
                          rows: Dict[int, np.ndarray]) -> None:
    from repro.serve import select_top_k
    for q in done:
        assert not q.expired, f"query {q.qid} expired in no-deadline run"
        row = rows[q.source]
        if q.target is not None:
            assert q.hops == int(row[q.target]), \
                (q.qid, q.served_by, q.hops, int(row[q.target]))
        elif q.k_nearest is not None:
            assert q.nearest == select_top_k(row, q.source, q.k_nearest), \
                (q.qid, q.served_by)
        else:
            assert np.array_equal(q.dist, row), (q.qid, q.served_by)


def _replay_certified(oracle: DistanceOracle, stream) -> int:
    """Deterministic certified count: the same stream against a bare
    oracle — no cache, no clock, no batching."""
    certified = 0
    for kind, s, t in stream:
        if kind == 0:
            certified += bool(oracle.query(s, t).exact)
        elif kind == 1:
            certified += oracle.top_k(s, K_NEAREST) is not None
        else:
            certified += oracle.landmark_row(s) is not None
    return certified


def _latency_stats(done: List[GraphQuery]) -> Dict[str, float]:
    lat = np.asarray([q.t_done - q.t_submit for q in done])
    span = max(max(q.t_done for q in done), 1e-12)
    return {
        "p50_latency_us": float(np.percentile(lat, 50) * 1e6),
        "p99_latency_us": float(np.percentile(lat, 99) * 1e6),
        "qps": float(len(done) / span),
    }


def _deadline_minirun(g) -> int:
    """Expired queries must be surfaced, not dropped: controlled-clock
    run whose deadlines all trip before the flush."""
    clock = _VirtualClock()
    svc = GraphService(g, max_batch=8, clock=clock)
    for i in range(4):
        svc.submit(GraphQuery(qid=i, source=i, target=g.n_nodes - 1,
                              deadline=0.01))
    clock.now = 1.0
    svc.flush()
    done = svc.drain_completed()
    assert len(done) == 4
    assert all(q.expired and q.served_by == "expired" for q in done)
    return svc.expired_count


def run(quick: bool = False, n_queries: Optional[int] = None,
        csv: Optional[List[str]] = None) -> Dict:
    names = QUICK_FAMILIES if quick else tuple(FAMILIES)
    nq = n_queries if n_queries is not None else \
        (20_000 if quick else 100_000)
    families = {}
    for fi, name in enumerate(names):
        g = FAMILIES[name]()
        pg = prepare_graph(g)
        pool, stream, arrivals = _make_stream(nq, g.n_nodes, seed=11 + fi)

        clock = _VirtualClock()
        svc = GraphService(pg.graph, max_batch=MAX_BATCH,
                           n_landmarks=N_LANDMARKS, row_cache_size=POOL,
                           completed_retention=None, clock=clock)
        done = _drive(svc, stream, arrivals, clock)
        assert len(done) == nq

        # exactness first, metrics second
        rows = _exact_rows(svc.prepared, pool)
        _assert_bit_identical(done, rows)

        certified = _replay_certified(
            DistanceOracle(svc.prepared, n_landmarks=N_LANDMARKS), stream)
        hits = svc.cache_hits + svc.oracle_hits
        row: Dict = {
            "n_nodes": g.n_nodes, "n_edges": g.n_edges,
            "n_queries": nq,
            "n_landmarks": svc.oracle.n_landmarks,
            "labels_checksum": svc.oracle.labels_checksum(),
            "certified_count": int(certified),
            "certified_fraction": round(certified / nq, 6),
            "hit_rate": round(hits / nq, 6),
            "cache_hits": svc.cache_hits,
            "oracle_hits": svc.oracle_hits,
            "sweep_served": svc.sweep_served,
            "offered_qps": OFFERED_QPS,
            "bit_identical": True,          # asserted above
        }
        row.update(_latency_stats(done))

        # advisory: warm tiered service vs exact-sweep-only on a smaller
        # stream (the exact-only config sweeps every query)
        n_cmp = min(400, nq)
        p50 = {}
        for label, kwargs in (
                ("oracle", dict(n_landmarks=N_LANDMARKS,
                                row_cache_size=POOL)),
                ("exact", dict(n_landmarks=0, row_cache_size=0))):
            c = _VirtualClock()
            s2 = GraphService(pg.graph, max_batch=MAX_BATCH, clock=c,
                              completed_retention=None, **kwargs)
            d2 = _drive(s2, stream[:n_cmp], arrivals[:n_cmp], c)
            p50[label] = _latency_stats(d2)["p50_latency_us"]
        row["p50_oracle_cmp_us"] = p50["oracle"]
        row["p50_exact_cmp_us"] = p50["exact"]
        row["oracle_p50_beats_exact"] = p50["oracle"] < p50["exact"]

        row["expired_surfaced"] = _deadline_minirun(g) == 4

        families[name] = row
        if csv is not None:
            csv.append(f"serving_{name},{row['p50_latency_us']:.1f},"
                       f"hit_rate={row['hit_rate']:.3f};"
                       f"certified={row['certified_fraction']:.3f};"
                       f"qps={row['qps']:.0f}")
    return {
        "benchmark": "bench_serving",
        "n_landmarks": N_LANDMARKS,
        "max_batch": MAX_BATCH,
        "families": families,
        "oracle_beats_exact_on": [n for n, r in families.items()
                                  if r["oracle_p50_beats_exact"]],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--queries", type=int, default=None)
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args()
    result = run(quick=args.quick, n_queries=args.queries)
    text = json.dumps(result, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)


if __name__ == "__main__":
    main()
