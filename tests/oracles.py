"""Pure-NumPy / SciPy shortest-path oracles shared by the test suite.

Deliberately independent of the library under test: queue BFS (the
paper's Alg. 3 semantics) is reimplemented here straight off the CSR
arrays — it does NOT call ``repro.core.bfs_queue_numpy``, so a bug in
the library's own baseline cannot mask an engine bug — and Dijkstra
comes from ``scipy.sparse.csgraph``.  Dtypes match what the engines
emit (int32 with -1 unreachable for BFS, float64/inf for Dijkstra) so
tests compare with ``assert_array_equal`` / ``assert_allclose``
directly.  Subprocess tests (``tests/test_distributed.py``) import this
module after ``sys.path.insert(0, "tests")``.
"""
from __future__ import annotations

from collections import deque

import numpy as np


def bfs_dist(g, source: int) -> np.ndarray:
    """Textbook queue BFS over the CSR arrays -> (n,) int32, -1 = unreachable."""
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    n = g.n_nodes
    dist = np.full(n, -1, dtype=np.int32)
    dist[source] = 0
    q = deque([source])
    while q:
        u = q.popleft()
        for e in range(indptr[u], indptr[u + 1]):
            v = indices[e]
            if v < n and dist[v] < 0:
                dist[v] = dist[u] + 1
                q.append(v)
    return dist


def bfs_dists(g, sources) -> np.ndarray:
    """Stacked queue-BFS distances -> (S, n) int32."""
    return np.stack([bfs_dist(g, int(s)) for s in np.asarray(sources)])


def bfs_sigma(g, source: int):
    """Queue BFS with shortest-path counting -> (dist int32, sigma
    float64, predecessor lists, stack order) — the textbook forward
    stage of Brandes, straight off the CSR arrays."""
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    n = g.n_nodes
    dist = np.full(n, -1, dtype=np.int32)
    sigma = np.zeros(n, dtype=np.float64)
    pred = [[] for _ in range(n)]
    dist[source] = 0
    sigma[source] = 1.0
    order = []
    q = deque([source])
    while q:
        u = q.popleft()
        order.append(u)
        for e in range(indptr[u], indptr[u + 1]):
            v = indices[e]
            if v >= n:
                continue
            if dist[v] < 0:
                dist[v] = dist[u] + 1
                q.append(v)
            if dist[v] == dist[u] + 1:
                sigma[v] += sigma[u]
                pred[v].append(u)
    return dist, sigma, pred, order


def bfs_sigmas(g, sources) -> np.ndarray:
    """Stacked shortest-path counts -> (S, n) float64 (0 unreachable)."""
    return np.stack([bfs_sigma(g, int(s))[1] for s in np.asarray(sources)])


def brandes_betweenness(g, sources=None) -> np.ndarray:
    """Textbook Brandes betweenness (directed, unnormalized, endpoints
    excluded) -> (n,) float64.  ``sources`` restricts the dependency
    sums (the source-sampled estimator); default: all nodes (exact).
    Deliberately independent of the library's batched level-parallel
    accumulation: per-source predecessor lists and an explicit
    reverse-BFS-order stack."""
    n = g.n_nodes
    sources = range(n) if sources is None else np.asarray(sources)
    bc = np.zeros(n, dtype=np.float64)
    for s in sources:
        s = int(s)
        _, sigma, pred, order = bfs_sigma(g, s)
        delta = np.zeros(n, dtype=np.float64)
        for w in reversed(order):
            for v in pred[w]:
                delta[v] += sigma[v] / sigma[w] * (1.0 + delta[w])
            if w != s:
                bc[w] += delta[w]
    return bc


def closeness_centrality(g, sources=None) -> np.ndarray:
    """Wasserman-Faust closeness over OUTGOING distances -> float64."""
    n = g.n_nodes
    sources = np.arange(n) if sources is None else np.asarray(sources)
    out = np.zeros(len(sources), np.float64)
    for i, s in enumerate(sources):
        dist = bfs_dist(g, int(s))
        reach = dist > 0
        r = int(reach.sum())
        tot = int(dist[reach].sum())
        out[i] = (r / max(n - 1, 1)) * (r / tot) if tot > 0 else 0.0
    return out


def harmonic_centrality(g, sources=None) -> np.ndarray:
    """Harmonic centrality H(u) = Σ_{v≠u} 1/d(u,v) -> float64."""
    sources = np.arange(g.n_nodes) if sources is None else \
        np.asarray(sources)
    out = np.zeros(len(sources), np.float64)
    for i, s in enumerate(sources):
        dist = bfs_dist(g, int(s))
        out[i] = (1.0 / dist[dist > 0]).sum()
    return out


def eccentricities(g, sources=None) -> np.ndarray:
    """Per-source eccentricity over reachable targets -> int32 (0 when
    nothing is reachable)."""
    sources = np.arange(g.n_nodes) if sources is None else \
        np.asarray(sources)
    out = np.zeros(len(sources), np.int32)
    for i, s in enumerate(sources):
        out[i] = int(bfs_dist(g, int(s)).max(initial=0))
    return out


def dijkstra_dist(g, weights, source: int) -> np.ndarray:
    """scipy Dijkstra -> (n,) float64, +inf = unreachable.  ``weights``
    may cover the padded edge lanes; only the first ``n_edges`` are read."""
    import scipy.sparse as sp
    import scipy.sparse.csgraph as csgraph
    src, dst = g.edge_arrays_np()
    mat = sp.csr_matrix((np.asarray(weights[: g.n_edges], np.float64),
                         (src, dst)), shape=(g.n_nodes, g.n_nodes))
    return csgraph.dijkstra(mat, indices=source, directed=True)


def dijkstra_dists(g, weights, sources) -> np.ndarray:
    """Stacked Dijkstra distances -> (S, n) float64."""
    return np.stack([dijkstra_dist(g, weights, int(s))
                     for s in np.asarray(sources)])
