"""Shared test fixtures."""
import numpy as np
import pytest

from repro.graph.csr import CSRGraph


@pytest.fixture
def random_weighted():
    """Factory fixture: seeded random directed graph + non-negative f32
    edge weights over the padded lanes — the graphs both the
    sweep-equivalence and the kernel-equivalence suites run on."""
    def make(n, avg_deg, seed):
        rng = np.random.default_rng(seed)
        m = max(1, int(n * avg_deg))
        g = CSRGraph.from_edges(rng.integers(0, n, m),
                                rng.integers(0, n, m), n)
        w = rng.uniform(0.1, 5.0, g.m_pad).astype(np.float32)
        return g, w
    return make
